"""kernels/lut_eval: on-device mapped-netlist execution vs the numpy
fold, the jnp scan oracle, and the per-sample gather oracle (Pallas in
interpret mode on CPU, same pattern as kernels/aig_sim)."""
import jax.numpy as jnp
import numpy as np
from hyp_compat import given, settings, st

from repro.kernels.lut_eval import (lut_eval, lut_eval_gather_ref,
                                    lut_eval_ref)
from repro.synth import (AIG, compile_device_plan, execute_packed,
                         execute_packed_pallas, input_patterns, random_words,
                         synthesize, unpack_bits)
from repro.synth.executor import _compile_plan
from repro.synth.from_sop import table_to_aig


def _random_mapped(seed: int, n_vars: int, n_outs: int, density=0.5):
    rng = np.random.default_rng(seed)
    aig = AIG(n_vars)
    aig.outputs = [
        table_to_aig(aig, rng.random(1 << n_vars) < density, None,
                     [2 * (i + 1) for i in range(n_vars)])
        for _ in range(n_outs)]
    return synthesize(aig)


def test_pallas_matches_numpy_fold_ragged():
    """Ragged word counts (not a multiple of the kernel block) pad
    transparently and match the host fold bit-exactly."""
    mapped = _random_mapped(0, 9, 3)
    assert mapped.n_luts > 1
    for n_words in (1, 7, 130):
        words = random_words(mapped.n_pis, n_words, seed=n_words)
        np.testing.assert_array_equal(
            execute_packed(mapped, words),
            execute_packed_pallas(mapped, words))


def test_device_plan_shape_and_padding():
    mapped = _random_mapped(1, 8, 2)
    dp = compile_device_plan(mapped)
    lvl = mapped.levels()
    widths = {}
    for l in mapped.luts:
        widths[lvl[l.root]] = widths.get(lvl[l.root], 0) + 1
    assert dp.n_levels == len(widths)
    assert dp.level_width == max(widths.values())
    assert dp.leaf_idx.shape == (dp.n_levels, dp.level_width, mapped.k)
    assert dp.tt_bits.shape == (dp.n_levels, dp.level_width, 1 << mapped.k)
    # padded slots: all-zero masks, const leaves, dump-row output
    n_pad = dp.n_levels * dp.level_width - mapped.n_luts
    assert int((dp.out_wires == dp.n_wires).sum()) == n_pad
    assert not dp.tt_bits[dp.out_wires == dp.n_wires].any()
    assert not dp.leaf_idx[dp.out_wires == dp.n_wires].any()


def test_scan_and_gather_oracles_match():
    mapped = _random_mapped(2, 9, 2)
    dp = compile_device_plan(mapped, _compile_plan(mapped))
    words = random_words(mapped.n_pis, 5, seed=3)
    want = execute_packed(mapped, words)

    plane = np.asarray(lut_eval_ref(
        jnp.asarray(words.view(np.int32)),
        jnp.asarray(dp.leaf_idx.reshape(-1, dp.k), jnp.int32),
        jnp.asarray(np.ascontiguousarray(
            dp.tt_bits.reshape(-1, 1 << dp.k)).view(np.int32)),
        jnp.asarray(dp.out_wires.reshape(-1), jnp.int32),
        dp.n_pis, dp.n_wires)).view(np.uint32)
    out = plane[dp.out_idx]
    out[dp.out_neg] = ~out[dp.out_neg]
    np.testing.assert_array_equal(out, want)

    n_samples = 5 * 32
    bits = unpack_bits(words, n_samples).astype(np.int32)
    gplane = np.asarray(lut_eval_gather_ref(
        jnp.asarray(bits), jnp.asarray(dp.leaf_idx),
        jnp.asarray((dp.tt_bits & 1).astype(np.int32)),
        jnp.asarray(dp.out_wires), dp.n_pis, dp.n_wires))
    gout = gplane[dp.out_idx].astype(np.uint8)
    gout[dp.out_neg] = 1 - gout[dp.out_neg]
    np.testing.assert_array_equal(gout, unpack_bits(want, n_samples))


def test_trivial_constant_network():
    """A constant function maps to zero LUTs; the wrapper's no-slot path
    still produces the complemented constant plane."""
    aig = AIG(3)
    aig.outputs = [1]           # const-1 literal
    mapped = synthesize(aig)
    assert mapped.n_luts == 0
    words = random_words(3, 4, seed=0)
    np.testing.assert_array_equal(
        execute_packed(mapped, words),
        execute_packed_pallas(mapped, words))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 5), n_outs=st.integers(1, 3), data=st.data())
def test_lut_eval_exhaustive_property(n, n_outs, data):
    """Random mapped netlists agree with the host fold on every input
    pattern through the Pallas kernel (exhaustive packed simulation)."""
    aig = AIG(n)
    aig.outputs = [
        table_to_aig(
            aig,
            np.array([bool((tt >> r) & 1) for r in range(1 << n)]),
            None, [2 * (i + 1) for i in range(n)])
        for tt in (data.draw(st.integers(0, (1 << (1 << n)) - 1))
                   for _ in range(n_outs))]
    mapped = synthesize(aig)
    pats = input_patterns(n)
    np.testing.assert_array_equal(
        execute_packed(mapped, pats),
        execute_packed_pallas(mapped, pats))


# ---------------------------------------------------------------------------
# Streamed/tiled kernel (TilePlan route) and the executor-engine registry
# ---------------------------------------------------------------------------

def test_streamed_matches_numpy_fold_ragged():
    """Both gather modes of the streamed kernel match the host fold
    bit-exactly on ragged word counts."""
    from repro.synth import execute_packed_streamed
    mapped = _random_mapped(0, 9, 3)
    for n_words in (1, 7, 130):
        words = random_words(mapped.n_pis, n_words, seed=n_words)
        want = execute_packed(mapped, words)
        for gather in ("fancy", "dma"):
            np.testing.assert_array_equal(
                want, execute_packed_streamed(mapped, words, gather=gather))


def test_streamed_constant_network():
    from repro.synth import execute_packed_streamed
    aig = AIG(3)
    aig.outputs = [1]           # const-1 literal
    mapped = synthesize(aig)
    assert mapped.n_luts == 0
    words = random_words(3, 4, seed=0)
    np.testing.assert_array_equal(
        execute_packed(mapped, words),
        execute_packed_streamed(mapped, words))


def test_streamed_multi_tile_levels():
    """tile_rows smaller than every level forces multi-tile bands (and
    gather reuse across tiles); results stay bit-identical."""
    from repro.synth import compile_tile_plan, execute_packed_streamed
    from repro.synth.executor import _compile_plan as cp
    mapped = _random_mapped(4, 10, 4)
    plan = cp(mapped)
    tp = compile_tile_plan(plan, mapped.n_pis, mapped.k, tile_rows=8)
    assert tp.n_tiles > len(plan.levels)     # levels actually split
    words = random_words(mapped.n_pis, 9, seed=2)
    want = execute_packed(mapped, words)
    for gather in ("fancy", "dma"):
        np.testing.assert_array_equal(
            want, execute_packed_streamed(mapped, words, tplan=tp,
                                          gather=gather))


def test_tile_plan_structure():
    from repro.synth import compile_tile_plan
    from repro.synth.executor import _compile_plan as cp
    mapped = _random_mapped(5, 9, 3)
    plan = cp(mapped)
    T = 16
    tp = compile_tile_plan(plan, mapped.n_pis, mapped.k, tile_rows=T)
    # bands are contiguous multiples of T starting after the PI rows
    assert tp.out_base[0] == 1 + mapped.n_pis
    assert ((np.diff(tp.out_base) % T) == 0).all()
    assert tp.n_rows == tp.out_base[-1] + T
    # staged-gather remap reproduces the direct leaf rows exactly
    staged = tp.gather_rows[np.arange(tp.n_tiles)[:, None, None],
                            tp.leaf_loc]
    np.testing.assert_array_equal(staged, tp.leaf_tiles)
    # every leaf row precedes its tile's band (topological tile order)
    assert (tp.leaf_tiles < tp.out_base[:, None, None]).all()
    # row_of_wire is a bijection onto real (non-pad) rows
    rows = tp.row_of_wire
    assert len(np.unique(rows)) == rows.shape[0]


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 5), n_outs=st.integers(1, 3),
       tile_rows=st.sampled_from([1, 2, 8, 32]), data=st.data())
def test_streamed_exhaustive_property(n, n_outs, tile_rows, data):
    """Random mapped netlists agree with the host fold on every input
    pattern through the streamed kernel, at tile sizes from degenerate
    (1 slot/tile) to larger-than-any-level."""
    from repro.synth import compile_tile_plan, execute_packed_streamed
    from repro.synth.executor import _compile_plan as cp
    aig = AIG(n)
    aig.outputs = [
        table_to_aig(
            aig,
            np.array([bool((tt >> r) & 1) for r in range(1 << n)]),
            None, [2 * (i + 1) for i in range(n)])
        for tt in (data.draw(st.integers(0, (1 << (1 << n)) - 1))
                   for _ in range(n_outs))]
    mapped = synthesize(aig)
    tp = compile_tile_plan(cp(mapped), mapped.n_pis, mapped.k,
                           tile_rows=tile_rows)
    pats = input_patterns(n)
    np.testing.assert_array_equal(
        execute_packed(mapped, pats),
        execute_packed_streamed(mapped, pats, tplan=tp))


def test_over_vmem_netlist_runs_streamed():
    """A wire plane exceeding the monolithic kernel's VMEM budget fails
    plan validation as before — but the streamed engine executes it
    argmax-identically to the numpy fold (the whole point of tiling)."""
    from repro.check import validate_device_plan
    from repro.synth import (compile_device_plan, compile_tile_plan,
                             execute_packed_streamed)
    from repro.synth.executor import _compile_plan as cp
    from repro.check import estimate_tile_vmem_bytes
    from repro.check.plan_check import estimate_vmem_bytes
    mapped = _random_mapped(6, 10, 16)
    dp = compile_device_plan(mapped)
    dp_t = compile_device_plan(mapped, tile_rows=8)
    # a budget between the tiled working set and the whole-plane
    # footprint: the monolithic plan is rejected at it
    mono = estimate_vmem_bytes(dp)
    tiled = estimate_tile_vmem_bytes(dp_t.tiles)
    assert tiled < mono          # tiling shrinks the working set
    budget = (mono + tiled) // 2
    rep = validate_device_plan(dp, vmem_budget_bytes=budget,
                               use_cache=False)
    assert any(i.code == "vmem-budget" for i in rep.issues)
    # the same netlist with a tile schedule passes the same budget...
    rep_t = validate_device_plan(dp_t, vmem_budget_bytes=budget,
                                 use_cache=False)
    assert rep_t.ok, [str(i) for i in rep_t.issues]
    # ...and executes bit-identically (hence argmax-identically)
    words = random_words(mapped.n_pis, 33, seed=7)
    np.testing.assert_array_equal(
        execute_packed(mapped, words),
        execute_packed_streamed(mapped, words, tplan=dp_t.tiles))


def test_plan_check_tile_budget_reject():
    """Tile working sets over budget are rejected with the tile-aware
    message; corrupted tile schedules are caught structurally."""
    from repro.check import validate_device_plan
    from repro.synth import compile_device_plan
    mapped = _random_mapped(7, 9, 3)
    dp = compile_device_plan(mapped, tile_rows=32)
    rep = validate_device_plan(dp, vmem_budget_bytes=1024,
                               use_cache=False)
    assert any(i.code == "vmem-budget" and "tile" in i.message
               for i in rep.issues)
    # corrupt the staged-gather remap: structural tile check fires
    dp.tiles.gather_rows = dp.tiles.gather_rows.copy()
    dp.tiles.gather_rows[0, 0] = dp.tiles.gather_rows[0, 0] + 1 \
        if dp.tiles.gather_cap > 0 else 0
    rep2 = validate_device_plan(dp, use_cache=False)
    assert any(i.code == "tile-gather" for i in rep2.issues)


def test_executor_registry_typed_error_and_custom_engine():
    from repro.synth import executors
    from repro.synth.executor import BitplaneNetwork, _NumpyExecutor

    with np.testing.assert_raises(executors.UnknownEngineError):
        executors.get("definitely-not-an-engine")
    try:
        executors.get("definitely-not-an-engine")
    except executors.UnknownEngineError as e:
        assert "numpy" in str(e) and "pallas-streamed" in str(e)
        assert "pallas" in e.known
    for builtin in ("numpy", "pallas", "pallas-streamed"):
        assert builtin in executors.names()


def test_autotune_cache_concurrent_writers(tmp_path, monkeypatch):
    """Many threads recording tuned shapes into one cache file: the
    mkstemp+replace write means the file is a valid JSON snapshot at
    every instant and no entry is torn — a pid-suffixed temp name would
    let two threads of this one process interleave."""
    import json
    import threading

    from repro.kernels.lut_eval import autotune

    path = tmp_path / "tiles.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    stop = threading.Event()
    torn = []

    def reader():
        while not stop.is_set():
            if path.exists():
                try:
                    json.loads(path.read_text())
                except ValueError as e:        # torn/partial write
                    torn.append(e)

    def writer(i):
        for j in range(25):
            autotune.record(f"fp{i}", "cpu", False,
                            tile_rows=32, block_w=128, us=float(j))

    r = threading.Thread(target=reader)
    ws = [threading.Thread(target=writer, args=(i,)) for i in range(4)]
    r.start()
    for w in ws:
        w.start()
    for w in ws:
        w.join()
    stop.set()
    r.join()
    assert not torn
    # every fingerprint landed (last-write-wins per key, no lost keys
    # is NOT guaranteed across writers — but each writer's own final
    # key must be readable)
    final = json.loads(path.read_text())
    assert final, "cache file empty after concurrent writes"
    for key, ent in final.items():
        assert ent["tile_rows"] == 32 and ent["block_w"] == 128
    assert autotune.lookup(next(iter(final)).split(":")[0], "cpu",
                           False) == (32, 128)
    assert not list(tmp_path.glob("*.tmp")), "leaked temp files"
