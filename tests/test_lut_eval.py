"""kernels/lut_eval: on-device mapped-netlist execution vs the numpy
fold, the jnp scan oracle, and the per-sample gather oracle (Pallas in
interpret mode on CPU, same pattern as kernels/aig_sim)."""
import jax.numpy as jnp
import numpy as np
from hyp_compat import given, settings, st

from repro.kernels.lut_eval import (lut_eval, lut_eval_gather_ref,
                                    lut_eval_ref)
from repro.synth import (AIG, compile_device_plan, execute_packed,
                         execute_packed_pallas, input_patterns, random_words,
                         synthesize, unpack_bits)
from repro.synth.executor import _compile_plan
from repro.synth.from_sop import table_to_aig


def _random_mapped(seed: int, n_vars: int, n_outs: int, density=0.5):
    rng = np.random.default_rng(seed)
    aig = AIG(n_vars)
    aig.outputs = [
        table_to_aig(aig, rng.random(1 << n_vars) < density, None,
                     [2 * (i + 1) for i in range(n_vars)])
        for _ in range(n_outs)]
    return synthesize(aig)


def test_pallas_matches_numpy_fold_ragged():
    """Ragged word counts (not a multiple of the kernel block) pad
    transparently and match the host fold bit-exactly."""
    mapped = _random_mapped(0, 9, 3)
    assert mapped.n_luts > 1
    for n_words in (1, 7, 130):
        words = random_words(mapped.n_pis, n_words, seed=n_words)
        np.testing.assert_array_equal(
            execute_packed(mapped, words),
            execute_packed_pallas(mapped, words))


def test_device_plan_shape_and_padding():
    mapped = _random_mapped(1, 8, 2)
    dp = compile_device_plan(mapped)
    lvl = mapped.levels()
    widths = {}
    for l in mapped.luts:
        widths[lvl[l.root]] = widths.get(lvl[l.root], 0) + 1
    assert dp.n_levels == len(widths)
    assert dp.level_width == max(widths.values())
    assert dp.leaf_idx.shape == (dp.n_levels, dp.level_width, mapped.k)
    assert dp.tt_bits.shape == (dp.n_levels, dp.level_width, 1 << mapped.k)
    # padded slots: all-zero masks, const leaves, dump-row output
    n_pad = dp.n_levels * dp.level_width - mapped.n_luts
    assert int((dp.out_wires == dp.n_wires).sum()) == n_pad
    assert not dp.tt_bits[dp.out_wires == dp.n_wires].any()
    assert not dp.leaf_idx[dp.out_wires == dp.n_wires].any()


def test_scan_and_gather_oracles_match():
    mapped = _random_mapped(2, 9, 2)
    dp = compile_device_plan(mapped, _compile_plan(mapped))
    words = random_words(mapped.n_pis, 5, seed=3)
    want = execute_packed(mapped, words)

    plane = np.asarray(lut_eval_ref(
        jnp.asarray(words.view(np.int32)),
        jnp.asarray(dp.leaf_idx.reshape(-1, dp.k), jnp.int32),
        jnp.asarray(np.ascontiguousarray(
            dp.tt_bits.reshape(-1, 1 << dp.k)).view(np.int32)),
        jnp.asarray(dp.out_wires.reshape(-1), jnp.int32),
        dp.n_pis, dp.n_wires)).view(np.uint32)
    out = plane[dp.out_idx]
    out[dp.out_neg] = ~out[dp.out_neg]
    np.testing.assert_array_equal(out, want)

    n_samples = 5 * 32
    bits = unpack_bits(words, n_samples).astype(np.int32)
    gplane = np.asarray(lut_eval_gather_ref(
        jnp.asarray(bits), jnp.asarray(dp.leaf_idx),
        jnp.asarray((dp.tt_bits & 1).astype(np.int32)),
        jnp.asarray(dp.out_wires), dp.n_pis, dp.n_wires))
    gout = gplane[dp.out_idx].astype(np.uint8)
    gout[dp.out_neg] = 1 - gout[dp.out_neg]
    np.testing.assert_array_equal(gout, unpack_bits(want, n_samples))


def test_trivial_constant_network():
    """A constant function maps to zero LUTs; the wrapper's no-slot path
    still produces the complemented constant plane."""
    aig = AIG(3)
    aig.outputs = [1]           # const-1 literal
    mapped = synthesize(aig)
    assert mapped.n_luts == 0
    words = random_words(3, 4, seed=0)
    np.testing.assert_array_equal(
        execute_packed(mapped, words),
        execute_packed_pallas(mapped, words))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 5), n_outs=st.integers(1, 3), data=st.data())
def test_lut_eval_exhaustive_property(n, n_outs, data):
    """Random mapped netlists agree with the host fold on every input
    pattern through the Pallas kernel (exhaustive packed simulation)."""
    aig = AIG(n)
    aig.outputs = [
        table_to_aig(
            aig,
            np.array([bool((tt >> r) & 1) for r in range(1 << n)]),
            None, [2 * (i + 1) for i in range(n)])
        for tt in (data.draw(st.integers(0, (1 << (1 << n)) - 1))
                   for _ in range(n_outs))]
    mapped = synthesize(aig)
    pats = input_patterns(n)
    np.testing.assert_array_equal(
        execute_packed(mapped, pats),
        execute_packed_pallas(mapped, pats))
