"""Attention correctness: chunked == full, windows, decode-vs-prefill."""
import jax
import jax.numpy as jnp
import numpy as np
from hyp_compat import given, settings, st

from repro.models import layers as L


def _qkv(rng, b, sq, sk, h, kv, dh):
    q = jnp.asarray(rng.normal(size=(b, sq, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sk, kv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sk, kv, dh)), jnp.float32)
    return q, k, v


@settings(max_examples=12, deadline=None)
@given(sq=st.integers(1, 65), chunk=st.sampled_from([4, 16, 32]),
       window=st.sampled_from([0, 8, 24]), h=st.sampled_from([2, 4]),
       kv=st.sampled_from([1, 2]), seed=st.integers(0, 50))
def test_chunked_equals_full(sq, chunk, window, h, kv, seed):
    rng = np.random.default_rng(seed)
    q, k, v = _qkv(rng, 2, sq, sq, h, kv, 8)
    full = L.full_attention(q, k, v, causal=True, window=window)
    chk = L.chunked_attention(q, k, v, causal=True, window=window,
                              chunk=chunk)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chk),
                               rtol=2e-4, atol=2e-4)


def test_window_masks_strictly():
    """With window w, position p attends only to (p-w, p]."""
    rng = np.random.default_rng(0)
    s, w = 32, 8
    q, k, v = _qkv(rng, 1, s, s, 2, 2, 4)
    out = L.full_attention(q, k, v, causal=True, window=w)
    # zeroing everything outside the window of the last query must not
    # change the last query's output
    k2 = k.at[:, : s - w].set(1e6)
    v2 = v.at[:, : s - w].set(1e6)
    out2 = L.full_attention(q, k2, v2, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(out[:, -1]),
                               np.asarray(out2[:, -1]), rtol=1e-4)


def test_decode_attention_matches_full():
    """Decode path against a filled cache == last row of full attention."""
    rng = np.random.default_rng(1)
    b, s, h, kv, dh = 2, 16, 4, 2, 8
    q, k, v = _qkv(rng, b, s, s, h, kv, dh)
    full = L.full_attention(q, k, v, causal=True)
    cpos = jnp.broadcast_to(jnp.arange(s), (b, s))
    pos = jnp.full((b,), s - 1, jnp.int32)
    dec = L.decode_attention(q[:, -1:], k, v, cpos, pos)
    np.testing.assert_allclose(np.asarray(full[:, -1:]), np.asarray(dec),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_ring_window():
    """Ring cache with window: empty (-1) and out-of-window slots ignored."""
    rng = np.random.default_rng(2)
    b, w, h, kv, dh = 1, 8, 2, 2, 4
    q = jnp.asarray(rng.normal(size=(b, 1, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, w, kv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, w, kv, dh)), jnp.float32)
    # slots hold positions 10..17 in ring order (pos % 8)
    cpos = jnp.asarray([[16, 17, 10, 11, 12, 13, 14, 15]])
    pos = jnp.asarray([17])
    out = L.decode_attention(q, k, v, cpos, pos, window=4)
    # only positions 14..17 are in-window; poisoning the others is a no-op
    poison_slots = jnp.asarray([2, 3, 4])  # positions 10, 11, 12
    k2 = k.at[:, poison_slots].set(1e6)
    v2 = v.at[:, poison_slots].set(1e6)
    out2 = L.decode_attention(q, k2, v2, cpos, pos, window=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), rtol=1e-4)


def test_gqa_repeat_equivalence():
    """GQA == MHA with explicitly repeated KV heads."""
    rng = np.random.default_rng(3)
    q, k, v = _qkv(rng, 2, 8, 8, 4, 2, 8)
    out_gqa = L.full_attention(q, k, v, causal=True)
    k_rep = L._repeat_kv(k, 2)
    v_rep = L._repeat_kv(v, 2)
    out_mha = L.full_attention(q, k_rep, v_rep, causal=True)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha),
                               rtol=1e-5)
