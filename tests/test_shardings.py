"""Sharding rules + data pipeline determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_arch
from repro.dist import shardings as sh
from repro.models import lm


def _fake_mesh(names=("data", "model"), shape=(1, 1)):
    dev = np.asarray(jax.devices()[:1]).reshape(*([1] * len(names)))
    # mesh of 1 device but correct axis names (rule tests only)
    return Mesh(dev, names)


class _FakeMesh:
    """Stands in for a (16, 16) mesh in pure rule tests."""
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_param_pspec_rules():
    cfg = get_arch("glm4-9b", smoke=True)
    shapes = jax.eval_shape(lambda k: lm.init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    specs = {sh._path_str(p): sh.param_pspec(p, l) for p, l in flat}
    assert specs["embed"] == P("model", "data")
    assert specs["lm_head"] == P("data", "model")
    assert specs["layers/attn/wq"] == P(None, "data", "model")
    assert specs["layers/attn/wo"] == P(None, "model", "data")
    assert specs["layers/mlp/w1"] == P(None, "data", "model")
    assert specs["layers/mlp/w2"] == P(None, "model", "data")
    assert specs["layers/ln1"] == P(None, None)  # (L, d) stacked norm
    assert specs["final_norm"] == P(None)


def test_param_pspec_moe_and_mamba():
    for arch, key_spec in [
        ("mixtral-8x22b", ("layers/moe/w1", P(None, None, "data", "model"))),
        ("falcon-mamba-7b", ("layers/mamba/in_proj",
                             P(None, "data", "model"))),
    ]:
        cfg = get_arch(arch, smoke=True)
        shapes = jax.eval_shape(lambda k: lm.init_params(cfg, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
        specs = {sh._path_str(p): sh.param_pspec(p, l) for p, l in flat}
        path, want = key_spec
        assert specs[path] == want, (arch, path, specs[path])


def test_dp_for_divisibility():
    m = _FakeMesh()
    assert sh._dp_for(m, 256) == "data"
    assert sh._dp_for(m, 1) is None
    assert sh._dp_for(m, 8) is None
    m2 = type("M", (), {"axis_names": ("pod", "data", "model"),
                        "shape": {"pod": 2, "data": 16, "model": 16}})()
    assert sh._dp_for(m2, 256) == ("pod", "data")
    assert sh._dp_for(m2, 2) == "pod"
    assert sh._dp_for(m2, 3) is None


def test_data_determinism():
    from repro.data.tokens import lm_batch, synth_tokens
    cfg = get_arch("glm4-9b", smoke=True)
    a = synth_tokens(cfg, 4, 64, seed=7, step=3)
    b = synth_tokens(cfg, 4, 64, seed=7, step=3)
    np.testing.assert_array_equal(a, b)
    c = synth_tokens(cfg, 4, 64, seed=7, step=4)
    assert not np.array_equal(a, c)
    toks, labels = lm_batch(cfg, 2, 32, 0, 0)
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])


def test_jsc_dataset_properties():
    from repro.data.jsc import make_jsc, train_test
    x, y = make_jsc(2000, seed=0)
    assert x.shape == (2000, 16) and y.shape == (2000,)
    assert set(np.unique(y)) <= set(range(5))
    # standardised features
    assert np.all(np.abs(x.std(0) - 1.0) < 0.2)
    # deterministic
    x2, y2 = make_jsc(2000, seed=0)
    np.testing.assert_array_equal(x, x2)
    # train/test disjoint seeds produce different data
    (xtr, _), (xte, _) = train_test(1000, 500)
    assert xtr.shape[0] == 1000 and xte.shape[0] == 500


def test_prefetcher():
    from repro.data.tokens import Prefetcher
    pf = Prefetcher(lambda step: step * 2, depth=2)
    got = [next(pf) for _ in range(5)]
    pf.close()
    assert got == [0, 2, 4, 6, 8]
